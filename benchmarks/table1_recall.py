"""Paper Table 1: recall at k=10, d=768, N=10,000, single grain.

Reproduces: isotropic gaussian (PCA captures ~k/d variance, Mode B needs a
big pool and still re-ranks to ~50%) vs anisotropic manifold (local PCA
captures >95%, Mode A/B candidate recall ~0.9, re-rank recall -> 1.0), and
the HNSW baseline (M=16, efSearch=50).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import HNTLConfig, build, search
from repro.core.flat import flat_search, recall_at_k
from repro.core.hnsw import HNSW
from repro.data import synthetic as syn


def run(n: int = 10_000, d: int = 768, nq: int = 100, k: int = 32, s: int = 8,
        hnsw_n: int | None = None, seed: int = 0):
    rows = []
    hnsw_n = hnsw_n or n
    for dataset, gen, pool, cand_note in [
        ("isotropic", lambda: syn.isotropic_gaussian(n, d, seed), 200, ""),
        ("anisotropic", lambda: syn.anisotropic_manifold(
            n, d, intrinsic=24, seed=seed), 20, ""),
    ]:
        x = gen()
        q = syn.queries_from(x, nq, seed=seed + 1)
        truth = flat_search(jnp.asarray(x), jnp.asarray(q), topk=10)

        cfg = HNTLConfig(d=d, k=k, s=s, n_grains=1, nprobe=1, pool=pool,
                         block=128)
        t0 = time.time()
        idx, info = build(x, cfg)
        build_s = time.time() - t0

        resA = search(idx, q, cfg, topk=10, mode="A")
        resB_cand = search(idx, q, cfg, topk=pool, mode="A")  # pool recall
        resB = search(idx, q, cfg, topk=10, mode="B")
        cand_recallA = recall_at_k(resA.ids, truth.ids)
        # candidate recall@10 within the pool of C
        hits = 0
        pred = np.asarray(resB_cand.ids)
        true = np.asarray(truth.ids)
        for p_row, t_row in zip(pred, true):
            hits += len(set(p_row.tolist()) & set(t_row.tolist()))
        cand_recall_pool = hits / true.size
        rerank = recall_at_k(resB.ids, truth.ids)

        rows.append(dict(dataset=dataset, mode="A",
                         var_captured=info.var_captured_mean,
                         cand_recall=cand_recallA, pool=pool,
                         rerank_recall=recall_at_k(resA.ids, truth.ids),
                         build_s=build_s))
        rows.append(dict(dataset=dataset, mode="B",
                         var_captured=info.var_captured_mean,
                         cand_recall=cand_recall_pool, pool=pool,
                         rerank_recall=rerank, build_s=build_s))

        # HNSW baseline (paper: M=16, efSearch=50)
        xh = x[:hnsw_n]
        th = flat_search(jnp.asarray(xh), jnp.asarray(q), topk=10)
        t0 = time.time()
        hnsw = HNSW(d=d, m=16, ef_construction=100, seed=0).build(xh)
        hb = time.time() - t0
        ids, _ = hnsw.search(q, topk=10, ef_search=50)
        rows.append(dict(dataset=dataset, mode="HNSW",
                         var_captured=float("nan"), cand_recall=float("nan"),
                         pool=0, rerank_recall=recall_at_k(ids, th.ids),
                         build_s=hb))
    return rows


def main(quick: bool = False):
    kw = dict(n=2000, nq=50, hnsw_n=2000) if quick else dict(hnsw_n=4000)
    rows = run(**kw)
    print("dataset,mode,var_captured,cand_recall,pool,rerank_recall")
    for r in rows:
        print(f"{r['dataset']},{r['mode']},{r['var_captured']:.3f},"
              f"{r['cand_recall']:.3f},{r['pool']},{r['rerank_recall']:.4f}")
    return rows


if __name__ == "__main__":
    main()
