"""Generate the EXPERIMENTS.md §Roofline table from results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def load(dir_: str, mesh: str = "pod1"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            # recompute terms uniformly from the raw per-device quantities
            c = r["flops"] / PEAK_FLOPS
            m = r["hbm_bytes"] / HBM_BW
            co = r["collective_bytes"].get("total", 0) / ICI_BW
            dom = max((("compute_s", c), ("memory_s", m),
                       ("collective_s", co)), key=lambda kv: kv[1])[0]
            r["roofline"] = {"compute_s": c, "memory_s": m,
                             "collective_s": co, "bottleneck": dom,
                             "compute_fraction": c / max(c, m, co, 1e-30)}
        rows.append(r)
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(rows, *, only_ok=True):
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful/HLO flops | peak GB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: "
                       f"{r.get('error','?')[:60]} | | | | | |")
            continue
        rf = r["roofline"]
        peak = r["bytes_per_device"].get("peak") or 0
        ratio = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['bottleneck'].replace('_s','')} | "
            f"{ratio:.2f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['bottleneck'].replace('_s','')} | - |")
        out[-1] += f" {peak/1e9:.2f} |"
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod1")
    args = ap.parse_args(argv)
    rows = load(args.dir, args.mesh)
    print(f"{len(rows)} cells ({sum(r['status']=='ok' for r in rows)} ok)\n")
    print(table(rows))


if __name__ == "__main__":
    main()
