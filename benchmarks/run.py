"""Benchmark harness: one module per paper table/claim.

  PYTHONPATH=src python -m benchmarks.run            # standard sizes
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-scale
  PYTHONPATH=src python -m benchmarks.run --only table2_scan
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    ("table1_recall", "Paper Table 1: recall iso/aniso, Mode A/B, HNSW"),
    ("table2_scan", "Paper Table 2: Block-SoA vs AoS vs pointer-chase"),
    ("scan_select", "Fused scan→select: O(Q·pool) candidate state vs "
                    "full materialize, gather-free fused path"),
    ("cascade", "Mixed-precision cascade: int4/int8 bytes/vector <= 0.6x "
                "fixed, staged-budget recall, BENCH_cascade.json"),
    ("memory_footprint", "Paper 3.2: 66 B/vec vs HNSW graph bytes"),
    ("sift_scale", "Paper 4: SIFT-like scale recall/QPS/DRAM"),
    ("segment_scale", "LSM store: fused stacked search vs per-segment loop"),
    ("churn", "Mutation plane: QPS/recall under delete+upsert churn, "
              "compaction reclaim"),
    ("drift", "Maintenance plane: recall under streaming drift, frozen "
              "partition vs split/merge/refit"),
    ("shard_scale", "Distributed plane: QPS + per-shard scan work vs shards"),
    ("serve_load", "Tenancy plane: many-tenant coalesced load — one "
                   "dispatch/window, zero re-stacks, zero leaks"),
    ("hntl_kv_decode", "HNTL-KV retrieval decode vs exact attention"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    failures = 0
    for name, desc in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name}: {desc} ===")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=args.quick)
            print(f"--- {name} done in {time.time()-t0:.1f}s")
        except Exception:                                  # noqa: BLE001
            failures += 1
            print(f"--- {name} FAILED:\n{traceback.format_exc()}")
    print(f"\n{len(BENCHES) - failures}/{len(BENCHES)} benchmarks ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
