"""Benchmark harness: one module per paper table/claim.

  PYTHONPATH=src python -m benchmarks.run            # standard sizes
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-scale
  PYTHONPATH=src python -m benchmarks.run --only table2_scan

A driver whose ``main(quick=...)`` returns a dict gets that dict written
to ``BENCH_<name>.json`` at the repo root (machine-readable QPS / recall /
latency / probe-count metrics; ``name`` is the module's ``BENCH_NAME``
attribute, defaulting to the module name).  Drivers that write their own
file and return None keep doing so.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCHES = [
    ("table1_recall", "Paper Table 1: recall iso/aniso, Mode A/B, HNSW"),
    ("table2_scan", "Paper Table 2: Block-SoA vs AoS vs pointer-chase"),
    ("scan_select", "Fused scan→select: O(Q·pool) candidate state vs "
                    "full materialize, gather-free fused path"),
    ("cascade", "Mixed-precision cascade: int4/int8 bytes/vector <= 0.6x "
                "fixed, staged-budget recall, BENCH_cascade.json"),
    ("memory_footprint", "Paper 3.2: 66 B/vec vs HNSW graph bytes"),
    ("sift_scale", "Paper 4: SIFT-like scale recall/QPS/DRAM"),
    ("segment_scale", "LSM store: fused stacked search vs per-segment loop"),
    ("churn", "Mutation plane: QPS/recall under delete+upsert churn, "
              "compaction reclaim"),
    ("drift", "Maintenance plane: recall under streaming drift, frozen "
              "partition vs split/merge/refit"),
    ("shard_scale", "Distributed plane: QPS + per-shard scan work vs shards"),
    ("routing_adaptive", "Adaptive routing: hub-aware probing + per-query "
                         "early termination — probe counts + QPS at "
                         "iso-recall on a skewed mix, BENCH_routing.json"),
    ("serve_load", "Tenancy plane: many-tenant coalesced load — one "
                   "dispatch/window, zero re-stacks, zero leaks"),
    ("coldtier", "Tiered residency: paged cold-tier search bit-identical "
                 "to the all-warm plane, QPS floor at 25% hot set, "
                 "BENCH_coldtier.json"),
    ("hntl_kv_decode", "HNTL-KV retrieval decode vs exact attention"),
]


def _write_bench(name: str, payload: dict) -> None:
    out = os.path.join(os.path.dirname(__file__), "..",
                       f"BENCH_{name}.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"--- wrote {os.path.relpath(out)}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    failures = 0
    for name, desc in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name}: {desc} ===")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            ret = mod.main(quick=args.quick)
            if isinstance(ret, dict):
                _write_bench(getattr(mod, "BENCH_NAME", name), ret)
            print(f"--- {name} done in {time.time()-t0:.1f}s")
        except Exception:                                  # noqa: BLE001
            failures += 1
            print(f"--- {name} FAILED:\n{traceback.format_exc()}")
    print(f"\n{len(BENCHES) - failures}/{len(BENCHES)} benchmarks ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
