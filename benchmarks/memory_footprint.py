"""Paper §3.2 memory comparison: HNTL compact index vs HNSW graph.

Claims reproduced: 66 B/vector DRAM for the compact tier (k=32 int16 coords
+ s=8 int8 sketch + u16 residual), ~3.1 MB HNSW structure overhead at
N=10,000 (64 B neighbour lists + headers), 4.7x less than the links alone;
Mode A additionally drops raw-vector DRAM residency entirely.
"""
from __future__ import annotations

import numpy as np

from repro.core import HNTLConfig, build, tree_bytes
from repro.core.hnsw import HNSW
from repro.data import synthetic as syn


def run(n: int = 10_000, d: int = 768, seed: int = 0,
        hnsw_n: int | None = None):
    # paper's 66 B/vec accounting: k=32 int16 coords + u16 residual (s=0)
    cfg = HNTLConfig(d=d, k=32, s=0, n_grains=max(1, n // 1024), block=128)
    x = syn.anisotropic_manifold(n, d, intrinsic=24, seed=seed)
    idx, info = build(x, cfg)

    hn = hnsw_n or n
    hnsw = HNSW(d=d, m=16, ef_construction=60, seed=0).build(x[:hn])
    graph_bytes = hnsw.graph_bytes() * (n / hn)       # scale to N (measured)
    # FAISS-style capacity accounting: level0 holds 2M slots, each upper
    # level M slots (expected levels/node = 1/ln(M)), + int64 offsets.
    import math
    cap_bytes = n * (4 * (2 * 16) + 8)         + int(n / math.log(16)) * 4 * 16
    hnsw_total = graph_bytes + n * d * 4              # + resident vectors

    compact = n * cfg.bytes_per_vector
    rows = [
        {"quantity": "hntl_bytes_per_vector", "value": cfg.bytes_per_vector},
        {"quantity": "hntl_compact_total_bytes", "value": compact},
        {"quantity": "hnsw_graph_bytes_measured", "value": int(graph_bytes)},
        {"quantity": "hnsw_graph_bytes_capacity", "value": int(cap_bytes)},
        {"quantity": "hnsw_total_bytes_with_vectors", "value": int(hnsw_total)},
        {"quantity": "graph_vs_compact_ratio_measured",
         "value": graph_bytes / compact},
        {"quantity": "graph_vs_compact_ratio_capacity",
         "value": cap_bytes / compact},
        {"quantity": "hnsw_total_vs_compact_ratio",
         "value": hnsw_total / compact},
        # Eq. 7 at the paper's block geometry (B=64, k=16, s=8) and ours
        {"quantity": "block_bytes_eq7_paper_geom",
         "value": 64 * (2 * 16 + 8 + 6)},
        {"quantity": "block_bytes_eq7_tpu_geom",
         "value": 128 * (2 * 32 + 8 + 6)},
    ]
    return rows


def main(quick: bool = False):
    rows = run(n=10_000, hnsw_n=1500 if quick else 4000)
    print("quantity,value")
    for r in rows:
        v = r["value"]
        print(f"{r['quantity']},{v:.2f}" if isinstance(v, float)
              else f"{r['quantity']},{v}")
    return rows


if __name__ == "__main__":
    main()
