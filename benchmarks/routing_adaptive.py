"""Adaptive query-time routing: probe counts + QPS at iso-recall.

The claim under test (ISSUE 9 tentpole): per-query early termination —
stop probing once the next grain's routing lower bound exceeds the
distance-gap margin over the query's best grain, with the hub set (top
routing-win grains) always probed — lets EASY queries scan 2-3 grains
while HARD queries keep the full ``nprobe``, so on a skewed query mix the
store does strictly less scan work at the same recall.

The mix is deliberately skewed the way serving traffic is: 80% easy
queries (perturbed members of a few popular clusters — which also makes
those clusters hubs) and 20% hard queries (cluster-boundary mixtures,
where several centroids tie and the stopping rule must keep probing).

Four assertions:
  1. *Iso-recall*: adaptive Recall@10 within 0.005 of the static plane
     (easy queries' neighbours live in their own grain; hard queries keep
     their probes).
  2. *Mean probe budget*: mean active probes < 0.6x the static nprobe.
  3. *Tail probe budget*: p99 active probes strictly below the static
     nprobe — even the hardest percentile terminates early somewhere.
  4. *QPS*: the two-phase bucketed adaptive dispatch beats the static
     plane on wall-clock QPS at that iso-recall (easy buckets re-enter
     ``search_stacked`` with a genuinely smaller static probe width).

Emits BENCH_routing.json at the repo root (QPS, recall, p50/p99 probe
counts) — also returned as a dict so ``benchmarks.run`` can emit it.

  PYTHONPATH=src python -m benchmarks.routing_adaptive [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import HNTLConfig, planner
from repro.core.store import VectorStore

BENCH_NAME = "routing"
OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_routing.json")

MARGIN = 0.35                     # distance-gap stopping margin under test


def _time(fn, iters: int, warmup: int = 2, reps: int = 3) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def _corpus(n: int, d: int, n_clusters: int, seed: int):
    """Well-separated Gaussian clusters: routing distances separate
    cleanly, so the grain structure the stopping rule exploits exists."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32) * 6.0
    per = n // n_clusters
    x = np.concatenate([
        centers[c] + rng.standard_normal((per, d)).astype(np.float32)
        for c in range(n_clusters)])
    return x, centers, rng


def _skewed_queries(x, centers, rng, nq: int, easy_frac: float):
    """Easy: perturbed members of the 4 most popular clusters (serving
    skew -> those clusters become hubs).  Hard: two-cluster boundary
    mixtures, where several routing distances tie."""
    n_easy = int(nq * easy_frac)
    d = x.shape[1]
    hot = rng.integers(0, 4, size=n_easy)          # skew: 4 hot clusters
    easy = (centers[hot]
            + 0.5 * rng.standard_normal((n_easy, d)).astype(np.float32))
    a, b = rng.integers(0, centers.shape[0], size=(2, nq - n_easy))
    hard = ((centers[a] + centers[b]) / 2
            + 1.5 * rng.standard_normal((nq - n_easy, d)).astype(np.float32))
    q = np.concatenate([easy, hard]).astype(np.float32)
    return q, n_easy


def _recall(ids, gt, topk: int) -> float:
    hit = sum(len(set(ids[i, :topk].tolist())
                  & set(gt[i, :topk].tolist())) for i in range(gt.shape[0]))
    return hit / (gt.shape[0] * topk)


def _probe_counts(st, q, *, nprobe: int, margin: float) -> np.ndarray:
    """Per-query active-probe counts of the store's CURRENT adaptive plan
    (including its accumulated hub set) — the [Q] vector the bucketed
    dispatch consumes, exposed for the percentile stats."""
    man = st.snapshot()
    entry = st._stacked_for(man.segments, None)
    stacked = st._live_plane(entry, man, st._clock())
    traffic = st._traffic_for(man.segments, stacked.index.routing.n_grains)
    hub = st._hub_mask_host(traffic)
    _, n_active, _, _ = planner.probe_plan(
        stacked, jnp.asarray(q), nprobe=nprobe, probe_margin=margin,
        min_probes=st.cfg.min_probes,
        hub_mask=jnp.asarray(hub) if hub is not None else None)
    return np.asarray(n_active)


def main(quick: bool = False):
    n = 8192 if quick else 32768
    d, n_clusters = 48, 32
    nprobe, pool, topk = 16, 32, 10
    nq = 128 if quick else 512
    iters = 3 if quick else 8

    x, centers, rng = _corpus(n, d, n_clusters, seed=0)
    q, n_easy = _skewed_queries(x, centers, rng, nq, easy_frac=0.8)
    gt = np.argsort(((x[None] - q[:, None]) ** 2).sum(-1), axis=1)[:, :topk]

    cfg = HNTLConfig(d=d, k=12, s=0, n_grains=n_clusters, nprobe=nprobe,
                     pool=pool, block=64, probe_margin=MARGIN, hub_size=2)
    st = VectorStore(cfg, seal_threshold=n)
    st.add(x)
    st.seal()

    skw = dict(topk=topk, mode="B")
    ids_static = np.asarray(st.search(q, **skw).ids)
    # warm the traffic counters so the hub set exists before measurement
    # (serving steady state), then measure the adaptive plan
    st.search(q, adaptive=True, **skw)
    ids_ad = np.asarray(st.search(q, adaptive=True, **skw).ids)

    r_static = _recall(ids_static, gt, topk)
    r_ad = _recall(ids_ad, gt, topk)
    na = _probe_counts(st, q, nprobe=nprobe, margin=MARGIN)
    mean_p, p50, p99 = (float(na.mean()), float(np.percentile(na, 50)),
                        float(np.percentile(na, 99)))
    hubs = st.hub_grains()
    print(f"  skewed mix: {n_easy}/{nq} easy; hubs={hubs.tolist()}")
    print(f"  Recall@{topk}: static {r_static:.3f}  adaptive {r_ad:.3f} "
          f"(margin={MARGIN})")
    print(f"  probes/query: static {nprobe}  adaptive mean {mean_p:.2f} "
          f"p50 {p50:.0f} p99 {p99:.0f}")
    assert r_ad >= r_static - 0.005, \
        f"adaptive Recall@{topk} {r_ad:.3f} vs static {r_static:.3f}: " \
        f"not iso-recall (want within 0.005)"
    assert mean_p < 0.6 * nprobe, \
        f"mean probes {mean_p:.2f} >= 0.6x static nprobe {nprobe}"
    assert p99 < nprobe, \
        f"p99 probes {p99:.0f} not strictly below static nprobe {nprobe}"

    f_static = lambda: np.asarray(st.search(q, **skw).ids)     # noqa: E731
    f_ad = lambda: np.asarray(st.search(                       # noqa: E731
        q, adaptive=True, **skw).ids)
    t_static, t_ad = _time(f_static, iters=iters), _time(f_ad, iters=iters)
    qps_static, qps_ad = nq / t_static, nq / t_ad
    lat_static, lat_ad = t_static / nq * 1e6, t_ad / nq * 1e6
    print(f"  QPS @ Q={nq}: static {qps_static:,.0f} q/s  ->  adaptive "
          f"{qps_ad:,.0f} q/s ({qps_ad / qps_static:.2f}x)")
    assert qps_ad > qps_static, \
        f"adaptive QPS {qps_ad:.0f} did not beat static {qps_static:.0f} " \
        f"at iso-recall"

    payload = {"n": n, "d": d, "quick": quick, "n_queries": nq,
               "easy_frac": round(n_easy / nq, 3),
               "probe_margin": MARGIN, "hub_size": cfg.hub_size,
               "hub_grains": [int(g) for g in hubs],
               "nprobe_static": nprobe,
               "probes_mean": round(mean_p, 2),
               "probes_p50": round(p50, 1), "probes_p99": round(p99, 1),
               "recall_at_10_static": round(r_static, 4),
               "recall_at_10_adaptive": round(r_ad, 4),
               "qps_static": round(qps_static, 1),
               "qps_adaptive": round(qps_ad, 1),
               "latency_us_static": round(lat_static, 1),
               "latency_us_adaptive": round(lat_ad, 1)}
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"  wrote {os.path.relpath(OUT)}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
